"""Jit'd public wrappers around the Pallas kernels.

Responsibilities:

* **Padding** to MXU/block-aligned shapes (head_dim -> multiple of 128,
  sequence -> block multiples, GMM dims -> tile multiples) and un-padding
  the result.  Zero/masked padding is exact for all three kernels.
* **Backend dispatch**: on TPU the kernels compile natively; everywhere else
  (this CPU container) they run under ``interpret=True``, which executes the
  kernel body through XLA — bit-for-bit the same program, minus the
  hardware.  The detection lives in :mod:`repro.kernels.backend` (shared by
  every kernel module, including the router-step kernel).
* **Autodiff**: Pallas calls have no automatic VJP.  Each op carries a
  ``jax.custom_vjp`` whose backward pass recomputes through the pure-jnp
  reference (flash/SSD) or through two more grouped matmuls (GMM, exact) —
  the standard fwd-kernel + recompute-bwd production compromise.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref
from .backend import use_interpret
from .flash_attention import flash_attention as _flash_pallas
from .moe_gmm import grouped_matmul_pallas as _gmm_pallas
from .ssd_scan import ssd_scan as _ssd_pallas

__all__ = ["flash_attention_op", "ssd_scan_op", "grouped_matmul"]

# deprecated alias — the detection's canonical home is kernels.backend
_interpret = use_interpret


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_op(q, k, v, causal: bool = True,
                       window: Optional[int] = None,
                       block_q: int = 512, block_k: int = 512):
    """q: (B, S, H, hd); k/v: (B, S, K, hd) -> (B, S, H, hd)."""
    return _flash_fwd_impl(q, k, v, causal, window, block_q, block_k)


def _flash_fwd_impl(q, k, v, causal, window, block_q, block_k):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    qt = _pad_to(q.transpose(0, 2, 1, 3), 3, 128)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 3, 128)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 3, 128)
    bq = min(block_q, max(16, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(16, 1 << (sk - 1).bit_length()))
    qt = _pad_to(qt, 2, bq)
    kt = _pad_to(kt, 2, bk)
    vt = _pad_to(vt, 2, bk)
    out = _flash_pallas(qt, kt, vt, causal=causal, window=window,
                        block_q=bq, block_k=bk, kv_len=sk,
                        sm_scale=hd ** -0.5,  # the UNpadded head_dim scale
                        interpret=_interpret())
    return out[:, :, :sq, :hd].transpose(0, 2, 1, 3)


def _flash_vjp_fwd(q, k, v, causal, window, block_q, block_k):
    return _flash_fwd_impl(q, k, v, causal, window, block_q, block_k), (q, k, v)


def _flash_vjp_bwd(causal, window, block_q, block_k, res, g):
    q, k, v = res

    def f(q_, k_, v_):
        out = _ref.flash_attention_ref(
            q_.transpose(0, 2, 1, 3), k_.transpose(0, 2, 1, 3),
            v_.transpose(0, 2, 1, 3), causal=causal, window=window)
        return out.transpose(0, 2, 1, 3)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention_op.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_scan_op(x, dt, B, C, A, chunk: int = 256):
    """x: (b, S, H, P); dt: (b, S, H); B/C: (b, S, G, N); A: (H,)."""
    return _ssd_fwd_impl(x, dt, B, C, A, chunk)


def _ssd_fwd_impl(x, dt, B, C, A, chunk):
    b, s, h, p = x.shape
    chunk = min(chunk, max(16, 1 << (s - 1).bit_length()))
    xt = _pad_to(x.transpose(0, 2, 1, 3), 2, chunk)
    dtt = _pad_to(dt.transpose(0, 2, 1), 2, chunk)   # dt=0 padding is exact
    Bt = _pad_to(B.transpose(0, 2, 1, 3), 2, chunk)
    Ct = _pad_to(C.transpose(0, 2, 1, 3), 2, chunk)
    y = _ssd_pallas(xt, dtt, Bt, Ct, A, chunk=chunk, interpret=_interpret())
    return y[:, :, :s].transpose(0, 2, 1, 3)


def _ssd_vjp_fwd(x, dt, B, C, A, chunk):
    return _ssd_fwd_impl(x, dt, B, C, A, chunk), (x, dt, B, C, A)


def _ssd_vjp_bwd(chunk, res, g):
    x, dt, B, C, A = res

    def f(x_, dt_, B_, C_, A_):
        y = _ref.ssd_scan_ref(x_.transpose(0, 2, 1, 3), dt_.transpose(0, 2, 1),
                              B_.transpose(0, 2, 1, 3), C_.transpose(0, 2, 1, 3),
                              A_)
        return y.transpose(0, 2, 1, 3)

    _, vjp = jax.vjp(f, x, dt, B, C, A)
    return vjp(g)


ssd_scan_op.defvjp(_ssd_vjp_fwd, _ssd_vjp_bwd)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

def grouped_matmul(lhs: jax.Array, rhs: jax.Array,
                   impl: Optional[str] = None) -> jax.Array:
    """(E, M, K) @ (E, K, N) -> (E, M, N).

    ``impl=None`` uses the XLA einsum (differentiable, fuses with
    neighbours); ``impl='pallas'`` uses the tiled kernel with an exact
    two-GMM backward.
    """
    if impl is None:
        return _ref.grouped_matmul_ref(lhs, rhs)
    if impl == "pallas":
        return _gmm_op(lhs, rhs)
    raise ValueError(f"unknown gmm impl {impl!r}")


@jax.custom_vjp
def _gmm_op(lhs, rhs):
    return _gmm_impl(lhs, rhs)


def _gmm_impl(lhs, rhs):
    e, m, k = lhs.shape
    n = rhs.shape[-1]
    bm = min(128, max(8, 1 << (m - 1).bit_length()))
    bn = min(128, max(128, 1 << (n - 1).bit_length())) if n >= 128 else 128
    bkk = min(512, max(128, 1 << (k - 1).bit_length())) if k >= 128 else 128
    lp = _pad_to(_pad_to(lhs, 1, bm), 2, bkk)
    rp = _pad_to(_pad_to(rhs, 1, bkk), 2, bn)
    out = _gmm_pallas(lp, rp, block_m=bm, block_n=bn, block_k=bkk,
                      interpret=_interpret())
    return out[:, :m, :n]


def _gmm_vjp_fwd(lhs, rhs):
    return _gmm_impl(lhs, rhs), (lhs, rhs)


def _gmm_vjp_bwd(res, g):
    lhs, rhs = res
    # d_lhs[e] = g[e] @ rhs[e]^T ; d_rhs[e] = lhs[e]^T @ g[e]  (exact)
    d_lhs = _gmm_impl(g, rhs.transpose(0, 2, 1)).astype(lhs.dtype)
    d_rhs = _gmm_impl(lhs.transpose(0, 2, 1), g).astype(rhs.dtype)
    return d_lhs, d_rhs


_gmm_op.defvjp(_gmm_vjp_fwd, _gmm_vjp_bwd)
