"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        kv_len: Optional[int] = None) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, K, Sk, hd).  Full-softmax reference."""
    b, h, sq, hd = q.shape
    _, kh, sk, _ = k.shape
    group = h // kh
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if kv_len is not None:
        mask &= k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, B, C, A) -> jax.Array:
    """Naive sequential SSD recurrence (the definition, token by token).

    x: (b, h, S, P); dt: (b, h, S); B/C: (b, g, S, N); A: (h,)
    """
    b, h, s, p = x.shape
    _, g, _, n = B.shape
    hg = h // g
    Bh = jnp.repeat(B, hg, axis=1)
    Ch = jnp.repeat(C, hg, axis=1)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp           # (b,h,p), (b,h), (b,h,n), (b,h,n)
        decay = jnp.exp(dtt * Af[None, :])[..., None, None]    # (b,h,1,1)
        upd = jnp.einsum("bhn,bhp->bhnp", bt, xt * dtt[..., None])
        state = decay * state + upd
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (xf.transpose(2, 0, 1, 3), dtf.transpose(2, 0, 1),
          Bh.transpose(2, 0, 1, 3).astype(jnp.float32),
          Ch.transpose(2, 0, 1, 3).astype(jnp.float32))
    _, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype)


def grouped_matmul_ref(lhs, rhs) -> jax.Array:
    """(E, M, K) @ (E, K, N) -> (E, M, N) in fp32 accumulation."""
    out = jnp.einsum("emk,ekn->emn", lhs.astype(jnp.float32),
                     rhs.astype(jnp.float32))
    return out.astype(lhs.dtype)
