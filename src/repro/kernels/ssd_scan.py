"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD computation for one head:

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T      (state: (N, P))
    y_t = C_t^T h_t

Chunked form (chunk length Q): within a chunk the quadratic "attention-like"
path computes the intra-chunk contribution with the decay matrix
``L[i,j] = exp(cumA_i - cumA_j)`` (i >= j), while the inter-chunk
contribution flows through the carried state.

TPU mapping: grid ``(batch, heads, chunks)`` with the *chunk* dimension
innermost — TPU grid steps run sequentially, so the inter-chunk state lives
in a VMEM scratch accumulator carried across chunk iterations.  This is the
same stream-past-local-state pattern as the flash kernel, and it is why the
kernel needs no global synchronization: the recurrence is a token queue of
depth one between consecutive chunks.

Block shapes: x (Q, P), B/C (Q, N), state (N, P); with the default
Q=256, P=64, N=128 the working set is ~0.5 MB fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, state_ref, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    bmat = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[0, 0].astype(jnp.float32)       # (Q, N)
    a = a_ref[0, 0]                              # scalar: A (negative)

    dA = dt * a                                  # (Q,) log-decay per step
    cum = jnp.cumsum(dA)                         # inclusive cumsum
    # decay from step j (exclusive) to step i (inclusive): exp(cum_i - cum_j)
    li = cum[:, None] - cum[None, :]             # (Q, Q)
    iota_q = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask before exp (masked entries have positive, overflowing exponents)
    L = jnp.exp(jnp.where(iota_k <= iota_q, li, -1e30))

    # intra-chunk (quadratic) path: y_intra = ((C B^T) * L) @ (dt * x)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(cb * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk path: y_inter_i = exp(cum_i) * C_i @ state_in
    state_in = state_ref[...]                    # (N, P)
    y_inter = jax.lax.dot_general(cmat * jnp.exp(cum)[:, None], state_in,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y + y_inter).astype(y_ref.dtype)

    # state update: state_out = exp(cum_Q) * state_in
    #             + sum_i exp(cum_Q - cum_i) * B_i (dt_i x_i)^T
    total = cum[chunk - 1]
    decay_out = jnp.exp(total - cum)             # (Q,)
    state_new = jax.lax.dot_general(bmat * decay_out[:, None], xdt,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(total) * state_in + state_new


def ssd_scan(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
             A: jax.Array, *, chunk: int = 256,
             interpret: Optional[bool] = None) -> jax.Array:
    """SSD over a full sequence.

    x:  (batch, heads, S, P)   — per-head inputs (dt NOT yet applied)
    dt: (batch, heads, S)      — positive step sizes
    B:  (batch, groups, S, N)  — input projections (groups divide heads)
    C:  (batch, groups, S, N)  — output projections
    A:  (heads,)               — negative per-head decay rates
    Returns y: (batch, heads, S, P).  S must be a multiple of ``chunk``
    (ops.py pads).  ``interpret=None`` picks the right mode for the host
    (kernels.backend).
    """
    b, h, s, p = x.shape
    _, g, _, n = B.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hg = h // g

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    a2 = jnp.broadcast_to(A.astype(jnp.float32)[None, :], (b, h))

    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, c_: (b_, h_, c_)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda b_, h_, c_, hg_=hg: (b_, h_ // hg_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda b_, h_, c_, hg_=hg: (b_, h_ // hg_, c_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, c_: (b_, h_)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda b_, h_, c_: (b_, h_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],  # carried state
        interpret=resolve_interpret(interpret),
    )(x, dt, B, C, a2)
